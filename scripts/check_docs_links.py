"""Link-check the docs spine: README.md + docs/*.md.

Validates every relative markdown link ``[text](target)``:

* the target file exists (resolved against the linking file's directory;
  absolute/external schemes — http(s), mailto — are skipped);
* a ``#anchor`` (own-file or cross-file) matches a heading in the target,
  using GitHub's slug rules (lowercase, drop punctuation, spaces to
  hyphens, ``-N`` suffixes for duplicates).

Exit code 0 when clean, 1 with one line per broken link otherwise — the
CI docs job runs this so the pointer map can't rot silently:

    python scripts/check_docs_links.py [--root .]
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str, seen: dict) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code ticks, lowercase,
    drop everything but word chars/spaces/hyphens, spaces -> hyphens,
    duplicate headings get -1, -2, ... suffixes."""
    # strip code ticks and asterisk emphasis; literal underscores survive
    # (GitHub keeps them — they are word chars)
    text = re.sub(r"[`*]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # linked headings
    slug = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    slug = slug.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def anchors_of(path: str) -> set:
    seen: dict = {}
    out = set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if m:
                out.add(github_slug(m.group(2), seen))
    return out


def links_of(path: str):
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                yield lineno, m.group(1)


def check(root: str) -> list:
    files = sorted(
        glob.glob(os.path.join(root, "README.md"))
        + glob.glob(os.path.join(root, "docs", "*.md")))
    errors = []
    anchor_cache: dict = {}
    for src in files:
        for lineno, target in links_of(src):
            if target.startswith(EXTERNAL):
                continue
            path_part, _, anchor = target.partition("#")
            if path_part:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(src), path_part))
                if not os.path.exists(dest):
                    errors.append(f"{src}:{lineno}: broken link -> {target}")
                    continue
            else:
                dest = src                      # own-file anchor
            if anchor:
                if not dest.endswith(".md") or os.path.isdir(dest):
                    continue                    # anchors into non-md: skip
                if dest not in anchor_cache:
                    anchor_cache[dest] = anchors_of(dest)
                if anchor not in anchor_cache[dest]:
                    errors.append(
                        f"{src}:{lineno}: missing anchor #{anchor} "
                        f"in {dest}")
    if not files:
        errors.append(f"no markdown files found under {root!r}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".")
    args = ap.parse_args(argv)
    errors = check(args.root)
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(glob.glob(os.path.join(args.root, "README.md"))
                  + glob.glob(os.path.join(args.root, "docs", "*.md")))
    if not errors:
        print(f"docs link-check OK ({n_files} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
