"""Quickstart: LoRA-SFT a small backbone on synthetic log-anomaly data and
generate with the tuned adapter.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import init_adapters, lora_scale
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import answer_accuracy, gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.serving.engine import Engine, ServeConfig
from repro.training.optimizers import adamw
from repro.training.train_step import make_lora_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=300, max_seq_len=192, lora_rank=8,
                      remat=False, dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    tok = ByteTokenizer()
    train = gen_log_dataset(rng, 200, source=0)
    test = gen_log_dataset(rng, 50, source=0)
    batcher = SFTBatcher(train, tok, 160, batch_size=8)

    adapters = init_adapters(jax.random.PRNGKey(1), cfg)
    opt = adamw(lr=3e-3)
    state = opt.init(adapters)
    step = jax.jit(make_lora_train_step(model, cfg, opt))
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batcher.sample().items()}
        adapters, state, m = step(params, adapters, state, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.3f} "
                  f"acc {float(m['accuracy']):.3f}")

    acc = answer_accuracy(model, cfg, params, adapters, test, tok, 160,
                          lora_scale(cfg))
    print(f"answer accuracy (yes/no): {acc:.3f}")

    eng = Engine(model, cfg, params, adapters)
    prompt = jnp.asarray([tok.encode(test[0].prompt)[:150]], jnp.int32)
    out = eng.generate(prompt, ServeConfig(batch_size=1, max_new_tokens=4,
                                           cache_len=192))
    print("prompt:", test[0].prompt[:60], "...")
    print("model says:", tok.decode(np.asarray(out)[0]),
          "| expected:", test[0].answer)


if __name__ == "__main__":
    main()
