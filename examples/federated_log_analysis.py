"""End-to-end FDLoRA driver: N ISP-like clients with non-IID log data run
Algorithm 1 (local learning -> federated dual-LoRA -> AdaFusion) and report
per-client accuracy + communication accounting.

    PYTHONPATH=src python examples/federated_log_analysis.py              # demo
    PYTHONPATH=src python examples/federated_log_analysis.py --preset 100m
      (the ~100M-parameter preset for a real machine; same code path)
"""
import argparse

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.data.partition import dirichlet_partition, train_test_split
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import answer_accuracy, gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.core.lora import lora_scale
from repro.models.api import get_model

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048),
}


def main():
    import jax
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=0.5)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"fdlora-{args.preset}", family="dense",
                      vocab_size=300, max_seq_len=192, lora_rank=8,
                      remat=False, dtype="float32", param_dtype="float32",
                      **PRESETS[args.preset])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"backbone: {cfg.count_params()/1e6:.1f}M params "
          f"(LoRA trains {cfg.count_lora_params()/1e3:.1f}K = "
          f"{100*cfg.count_lora_params()/cfg.count_params():.3f}%)")

    rng = np.random.default_rng(0)
    tok = ByteTokenizer()
    data = sum((gen_log_dataset(rng, 150, s) for s in range(3)), [])
    parts = dirichlet_partition(data, args.clients, args.alpha, rng)
    batchers, tests = [], []
    for i, p in enumerate(parts):
        tr, te = train_test_split(p, 0.2, rng)
        batchers.append(SFTBatcher(tr, tok, 160, batch_size=8, seed=i))
        tests.append(te)
        print(f"client {i}: {len(tr)} train / {len(te)} test")

    fed = FDLoRAConfig(n_clients=args.clients, rounds=args.rounds,
                       inner_steps=3, sync_every=max(args.rounds // 2, 1),
                       stage1_steps=15, inner_lr=3e-3, fusion_steps=5,
                       few_shot_k=8)
    trainer = FDLoRATrainer(model, cfg, fed, params)

    print("\n== Stage 1: local learning (personalized LoRA) ==")
    clients = trainer.stage1(batchers)
    print("global LoRA initialised to client mean (Eq. 6)")

    print("\n== Stage 2: federated dual-LoRA ==")
    trainer.stage2(clients, batchers)
    for h in trainer.history[-3:]:
        print(f"round {h['round']}: inner loss {h['loss']:.3f}")

    print("\n== Stage 3: AdaFusion ==")
    trainer.stage3(clients, batchers)
    for i, c in enumerate(clients):
        print(f"client {i}: fusion weights w=({c.fusion_weights[0]:.2f}, "
              f"{c.fusion_weights[1]:.2f})")

    print("\n== Evaluation ==")
    for i, c in enumerate(clients):
        fused = trainer.fused_adapters(c)
        acc = answer_accuracy(model, cfg, params, fused, tests[i], tok, 160,
                              lora_scale(cfg))
        mb = (c.comm_bytes_up + c.comm_bytes_down) / 2**20
        print(f"client {i}: accuracy {acc:.3f}  communicated {mb:.2f} MiB")


if __name__ == "__main__":
    main()
