"""Serving demo: batched decoding with AdaFusion-merged dual LoRA, plus the
fused Pallas serving kernel on the same weights (interpret mode on CPU).

    PYTHONPATH=src python examples/serve_fused.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dual_lora import merge
from repro.core.lora import init_adapters, lora_scale
from repro.data.tokenizer import ByteTokenizer
from repro.kernels.ops import fused_dual_lora_dense
from repro.models.api import get_model
from repro.serving.engine import Engine, ServeConfig


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=300, max_seq_len=128, lora_rank=8,
                      remat=False, dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()

    # two adapter sets standing in for a client's personalized + global LoRA
    ad_p = init_adapters(jax.random.PRNGKey(1), cfg)
    ad_s = init_adapters(jax.random.PRNGKey(2), cfg)
    w = jnp.array([0.7, 0.5])
    fused = merge(ad_p, ad_s, w)

    eng = Engine(model, cfg, params, adapters=fused)
    prompts = ["logs: job start | net link up anomaly? ",
               "logs: kernel panic cpu0 | fan speed set anomaly? "]
    batch = jnp.asarray([tok.encode(p)[:48] + [0] * (48 - len(tok.encode(p)[:48]))
                         for p in prompts], jnp.int32)
    out = eng.generate(batch, ServeConfig(batch_size=2, max_new_tokens=4,
                                          cache_len=128))
    for p, o in zip(prompts, np.asarray(out)):
        print(f"prompt: {p!r}\n  -> {tok.decode(o)!r}")

    # same math through the fused Pallas kernel (Eq. 7 merged on-chip)
    print("\nPallas dual-LoRA kernel vs jnp merge (wq of layer 0):")
    x = jax.random.normal(jax.random.PRNGKey(3), (4, cfg.d_model),
                          dtype=jnp.bfloat16)
    wq = params["blocks"]["b0"]["mixer"]["wq"][0].astype(jnp.bfloat16)
    lp = ad_p["blocks"]["b0"]["mixer"]["wq"]
    ls = ad_s["blocks"]["b0"]["mixer"]["wq"]
    y_kernel = fused_dual_lora_dense(
        x, wq, {"a": lp["a"][0], "b": lp["b"][0]},
        {"a": ls["a"][0], "b": ls["b"][0]}, w, lora_scale(cfg), block=128)
    fused_wq = fused["blocks"]["b0"]["mixer"]["wq"]
    y_ref = (x @ wq).astype(jnp.float32) + lora_scale(cfg) * (
        x.astype(jnp.float32) @ fused_wq["a"][0] @ fused_wq["b"][0])
    err = float(jnp.max(jnp.abs(y_kernel.astype(jnp.float32) - y_ref)))
    print(f"  max |kernel - reference| = {err:.5f}")


if __name__ == "__main__":
    main()
