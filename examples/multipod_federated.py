"""The multi-pod federated round on a real (local) mesh: runs one jitted
FDLoRA round with clients stacked on a mesh axis and shows the collective
schedule the compiler emitted — LoRA-sized cross-client traffic only.

    PYTHONPATH=src python examples/multipod_federated.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import parse_collectives
from repro.configs.base import ModelConfig
from repro.core.lora import init_adapters
from repro.core.outer_opt import make_outer_optimizer
from repro.federated.distributed import make_fdlora_round_step
from repro.models.api import get_model
from repro.training.optimizers import adamw


def main():
    cfg = ModelConfig(name="mp-demo", family="dense", n_layers=2, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=300,
                      max_seq_len=64, lora_rank=8, remat=False,
                      dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inner = adamw(lr=1e-3)
    outer = make_outer_optimizer("nesterov", lr=1e-3, momentum=0.5)
    K, N, B, S = 3, 2, 4, 32
    round_step = make_fdlora_round_step(model, cfg, inner, outer, K)

    theta_s = init_adapters(jax.random.PRNGKey(1), cfg)
    state = {"inner_opt": jax.tree.map(lambda x: jnp.stack([x] * N),
                                       inner.init(theta_s)),
             "outer_opt": outer.init(theta_s)}
    batches = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (N, K, B, S),
                                     0, cfg.vocab_size),
        "loss_mask": jnp.ones((N, K, B, S), jnp.int32),
    }

    jitted = jax.jit(round_step)
    theta_new, state, loss = jitted(params, theta_s, state, batches)
    print(f"one federated round: {N} clients x {K} inner steps, "
          f"loss {float(loss):.3f}")

    lowered = jitted.lower(params, theta_s, state, batches)
    colls = parse_collectives(lowered.compile().as_text())
    print(f"collectives in the compiled round: {len(colls)}")
    adapter_bytes = sum(l.size * l.dtype.itemsize
                        for l in jax.tree.leaves(theta_s))
    print(f"adapter tree size: {adapter_bytes/2**20:.2f} MiB — on the "
          f"production (2,16,16) mesh the ONLY cross-pod traffic is the "
          f"outer pseudo-gradient mean of exactly this tree, once per "
          f"{K}-step round (see EXPERIMENTS.md §Dry-run for the 512-chip "
          f"lowering).")


if __name__ == "__main__":
    main()
